"""Collective-traffic extraction from compiled HLO text.

``cost_analysis`` has no collective term, so we parse the (post-SPMD,
per-device) HLO: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op we sum the *operand* sizes — the bytes a
device injects into the interconnect for that op. Compiled HLO references
operands by name, so we first build a name -> output-shape-bytes map over
all instructions, then resolve the collective operands. Start/done pairs
(async collectives) are counted once via the start op.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]"
)
_CALL_RE = re.compile(
    r"(all-gather-start|all-gather-done|all-gather|"
    r"all-reduce-start|all-reduce-done|all-reduce|"
    r"reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute)"
    r"\(([^)]*)\)"
)
_OPERAND_RE = re.compile(r"%?([\w.-]+)")


def _shape_bytes_of(type_str: str) -> int:
    """Sum byte sizes of every array shape appearing in a type string
    (handles tuples like (f32[8,128], f32[8,128]))."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        dims = m.group(2)
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {'total_bytes': int, 'by_type': {op: bytes}, 'count': int}.

    Operand bytes per collective, summed over the whole module (loop bodies
    appear once — see dryrun.py's trip-count extrapolation).
    """
    # pass 1: name -> output type string
    shapes: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            name, rhs = m.group(1), m.group(2)
            # output type is the prefix of rhs up to the op name
            shapes[name] = rhs.split(" ")[0] if "[" in rhs.split(" ")[0] else rhs[:200]

    by_type: dict[str, int] = defaultdict(int)
    count = 0
    for line in lines:
        cm = _CALL_RE.search(line)
        if cm is None:
            continue
        op, operand_str = cm.group(1), cm.group(2)
        if op.endswith("-done"):
            continue
        base = op.replace("-start", "")
        nbytes = 0
        # operand shapes may be inline (typed form) or by-name (compiled form)
        inline = _shape_bytes_of(operand_str)
        if inline:
            nbytes = inline
        else:
            for om in _OPERAND_RE.finditer(operand_str):
                ref = shapes.get(om.group(1))
                if ref:
                    nbytes += _shape_bytes_of(ref)
        by_type[base] += nbytes
        count += 1
    return {
        "total_bytes": int(sum(by_type.values())),
        "by_type": {k: int(v) for k, v in by_type.items()},
        "count": count,
    }


def op_histogram(hlo_text: str, top: int = 15) -> dict:
    """Rough per-op-kind instruction counts (duplicate-op remat diagnostics)."""
    hist: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*[a-z0-9_\[\]{},. ]*?([a-z][a-z0-9-]*)\(", line)
        if m:
            hist[m.group(1)] += 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
