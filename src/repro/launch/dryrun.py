import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first init, and the production meshes need 512 placeholder host devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step, ...).lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus collective-byte extraction from the compiled HLO. Results are cached
as JSON under ``results_dryrun/`` for launch/roofline.py and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch internlm2_20b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --arch X --shape Y --tag blah \
        --override seq_shard_residual=False    # hillclimb knobs
"""
import argparse
import json
import time
import traceback

import jax

from .. import configs
from ..distributed.sharding import make_rules
from ..train.steps import make_decode_step, make_prefill_step, make_train_step
from . import specs
from .hlo_stats import collective_stats, op_histogram
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results_dryrun")


def _parse_override(s: str):
    key, _, val = s.partition("=")
    for cast in (int, float):
        try:
            return key, cast(val)
        except ValueError:
            pass
    if val in ("True", "False"):
        return key, val == "True"
    return key, val


def _lower_cell(cfg, shape, mesh, rules):
    """Lower+compile one module for (cfg, shape). Returns (compiled, timings)."""
    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt = specs.make_optimizer(cfg)
            params, opt_state = specs.model_state_specs(cfg, mesh, rules, True)
            batch = specs.batch_specs(cfg, shape, mesh, rules)
            fn = make_train_step(cfg, rules, opt)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            params, _ = specs.model_state_specs(cfg, mesh, rules, False)
            batch = specs.batch_specs(cfg, shape, mesh, rules)
            fn = make_prefill_step(cfg, rules, cache_len=shape.seq_len)
            lowered = jax.jit(fn).lower(params, batch)
        else:  # decode
            params, _ = specs.model_state_specs(cfg, mesh, rules, False)
            caches, token, pos = specs.decode_specs(cfg, shape, mesh, rules)
            fn = make_decode_step(cfg, rules)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(params, caches, token, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _cost_stats(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    colls = collective_stats(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": colls["total_bytes"],
        "coll_by_type": colls["by_type"],
        "coll_count": colls["count"],
    }


def _extrapolate(s1: dict, s2: dict, r: int) -> dict:
    """Linear trip-count extrapolation: F(R) = F1 + (R-1)(F2-F1).

    XLA's cost analysis (and the HLO text) count a while-loop body once, so
    the scanned-layers module under-reports per-layer work. Lowering the
    SAME step at 1 and 2 pattern-repeats gives the per-repeat increment
    exactly; everything outside the loop (embedding, lm_head, optimizer,
    gradient reductions) sits in the intercept."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        out[key] = s1[key] + (r - 1) * (s2[key] - s1[key])
    by = {}
    for k in set(s1["coll_by_type"]) | set(s2["coll_by_type"]):
        a, b = s1["coll_by_type"].get(k, 0), s2["coll_by_type"].get(k, 0)
        by[k] = a + (r - 1) * (b - a)
    out["coll_by_type"] = by
    out["coll_count"] = s1["coll_count"] + (r - 1) * (
        s2["coll_count"] - s1["coll_count"]
    )
    return out


def _recurrence_correction(cfg, shape) -> float:
    """Analytic FLOPs for the *inner* sequential recurrences (RWKV6 chunked
    WKV, Mamba selective scan) whose loop bodies XLA counts once. These are
    elementwise/VPU terms, small next to the MXU matmul flops, but we add
    them so SSM-family compute terms aren't understated. Documented in
    EXPERIMENTS.md §Roofline."""
    if shape.kind == "decode":
        return 0.0  # single-step recurrences lower loop-free
    B, S = shape.global_batch, shape.seq_len
    H, Dh = cfg.n_heads, cfg.head_dim
    Di, St = cfg.mamba_d_inner, cfg.mamba.d_state
    L = 16  # RWKV_CHUNK
    per_pattern = 0.0
    for kind in cfg.pattern:
        if kind.mixer == "rwkv6":
            per_pattern += B * H * S * (4 * L * Dh + 4 * Dh * Dh)
        elif kind.mixer == "mamba":
            per_pattern += 6.0 * B * S * Di * St
    fwd = per_pattern * cfg.n_repeats
    return fwd * (3.0 if shape.kind == "train" else 1.0)  # bwd ~ 2x fwd


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, keep_hlo: bool = False) -> dict:
    shape = configs.SHAPES[shape_name]
    cfg = configs.get(arch)
    if overrides:
        overrides = dict(overrides)
        cap = overrides.pop("capacity_factor", None)
        if cap is not None and cfg.moe is not None:
            import dataclasses
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
        if overrides:
            cfg = cfg.replace(**overrides)
    runnable, reason = configs.cell_runnable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "overrides": overrides or {},
        "status": "skipped" if not runnable else "pending",
        "skip_reason": reason,
    }
    if not runnable:
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, seq_shard_residual=cfg.seq_shard_residual,
                       kv_shard=cfg.decode_kv_shard,
                       expert_axis=cfg.moe_expert_axis, fsdp=cfg.fsdp_params)

    # 1) the REAL module: scanned layers — compile proof + memory analysis
    compiled, t_lower, t_compile = _lower_cell(cfg, shape, mesh, rules)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    # 2) cost modules: 1- and 2-repeat depth, unrolled attention chunks ->
    #    exact per-repeat cost increments, linearly extrapolated to full depth
    p = len(cfg.pattern)
    r = cfg.n_repeats
    enc_per_rep = max(1, cfg.n_enc_layers // r) if cfg.enc_dec else 0
    cost_cfg = cfg.replace(attn_unroll_chunks=True, scan_layers=False)
    if r >= 2:
        c1 = cost_cfg.replace(n_layers=p, n_enc_layers=enc_per_rep)
        c2 = cost_cfg.replace(n_layers=2 * p, n_enc_layers=2 * enc_per_rep)
        s1 = _cost_stats(_lower_cell(c1, shape, mesh, rules)[0])
        s2 = _cost_stats(_lower_cell(c2, shape, mesh, rules)[0])
        stats = _extrapolate(s1, s2, r)
    else:
        stats = _cost_stats(_lower_cell(cost_cfg, shape, mesh, rules)[0])
    rec_fix = _recurrence_correction(cfg, shape) / cell["chips"]

    n = cfg.param_counts()
    cell.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        # per-device numbers (XLA reports the per-replica SPMD module)
        flops_per_device=stats["flops"] + rec_fix,
        bytes_per_device=stats["bytes"],
        collective_bytes_per_device=stats["coll"],
        collective_by_type=stats["coll_by_type"],
        collective_count=stats["coll_count"],
        recurrence_flops_correction=rec_fix,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        params_total=n["total"],
        params_active=n["active"],
        op_histogram=op_histogram(hlo),
    )
    if keep_hlo:
        cell["hlo_path"] = os.path.join(
            RESULTS_DIR, f"{arch}.{shape_name}.{mesh_name}.hlo.txt"
        )
        with open(cell["hlo_path"], "w") as f:
            f.write(hlo)
    return cell


def cell_path(arch, shape_name, mesh_name, tag=""):
    suffix = f".{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}.{shape_name}.{mesh_name}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=list(configs.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for result files (hillclimb runs)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field overrides, e.g. seq_shard_residual=False")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    overrides = dict(_parse_override(s) for s in args.override) or None

    cells = []
    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                cells.append((arch, shape, multi))

    failures = 0
    for arch, shape, multi in cells:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        out = cell_path(arch, shape, mesh_name, args.tag)
        if os.path.exists(out) and not args.force:
            print(f"[cached] {arch} x {shape} x {mesh_name}")
            continue
        print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
        try:
            cell = run_cell(arch, shape, multi, overrides, args.keep_hlo)
        except Exception as e:  # a failure here is a bug in the system
            failures += 1
            cell = {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"  FAILED: {e}")
        with open(out, "w") as f:
            json.dump(cell, f, indent=1, sort_keys=True)
        if cell["status"] == "ok":
            print(
                f"  ok: compile={cell['compile_s']}s "
                f"flops/dev={cell['flops_per_device']:.3e} "
                f"coll/dev={cell['collective_bytes_per_device']:.3e}B "
                f"temp={cell['memory']['temp_bytes']/2**30:.2f}GiB"
            )
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
