"""Production meshes (assignment-fixed).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun.py, real launchers) must have set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` (dry-run) or be on
real hardware before the first call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
