"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

Weak-type-correct, shardable, no device allocation. The modality frontends
are stubs per the assignment: ``[audio]`` provides precomputed frame
embeddings (S/4 encoder positions), ``[vlm]`` precomputed patch embeddings
(first S/8 positions) plus the 3-stream M-RoPE position ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import Shape
from ..configs.base import ModelConfig
from ..distributed.sharding import ShardingRules
from ..models import transformer as T
from ..models.params import abstract_params
from ..optim.adamw import AdamW


def _sds(shape, dtype, mesh, spec):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _dp_axes(rules: ShardingRules | None, batch: int):
    if rules is None:
        return None
    total_dp = 1
    for a in rules.dp:
        total_dp *= rules.mesh.shape[a]
    if batch % total_dp != 0 or batch < total_dp:
        return None  # tiny batch (long_500k): replicate batch dim
    return rules._dp()


def batch_specs(cfg: ModelConfig, shape: Shape, mesh, rules) -> dict:
    """Inputs for train/prefill entry points."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_axes(rules, B)
    out = {"tokens": _sds((B, S), jnp.int32, mesh, P(dp, None))}
    if cfg.enc_dec:
        out["encoder_embeds"] = _sds(
            (B, S // cfg.enc_len_ratio, cfg.d_model), jnp.bfloat16, mesh,
            P(dp, None, None),
        )
    if cfg.vision_len_ratio:
        out["vision_embeds"] = _sds(
            (B, S // cfg.vision_len_ratio, cfg.d_model), jnp.bfloat16, mesh,
            P(dp, None, None),
        )
        out["positions3"] = _sds((3, B, S), jnp.int32, mesh, P(None, dp, None))
    return out


def decode_specs(cfg: ModelConfig, shape: Shape, mesh, rules) -> tuple:
    """(caches, token, pos) for the decode entry point. The KV cache /
    SSM-state stand-ins represent a context of ``shape.seq_len`` tokens."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_axes(rules, B)
    enc_len = S // cfg.enc_len_ratio if cfg.enc_dec else 0
    caches = T.abstract_cache(
        cfg, rules, batch=B, cache_len=S, enc_len=enc_len, mesh=mesh
    )
    token = _sds((B, 1), jnp.int32, mesh, P(dp, None))
    pos = _sds((), jnp.int32, mesh, P())
    return caches, token, pos


def _zero1_defs(defs, rules):
    """ZeRO-1: Adam moments additionally sharded over 'data' on their first
    replicated, divisible dim. Params stay as laid out (no weight regather;
    only the optimizer update communicates). See EXPERIMENTS.md §Perf."""
    from ..models.params import ParamDef

    data_size = rules.mesh.shape.get("data", 1) if rules else 1

    def one(d):
        if not isinstance(d, ParamDef):
            return {k: one(v) for k, v in d.items()}
        spec = tuple(d.spec)
        for i, s in enumerate(d.shape):
            ax = spec[i] if i < len(spec) else None
            if ax is None and s % data_size == 0 and s >= data_size:
                new = list(spec) + [None] * (len(d.shape) - len(spec))
                new[i] = "data"
                return ParamDef(d.shape, P(*new), d.init, d.scale)
        return d

    return one(defs)


def model_state_specs(cfg: ModelConfig, mesh, rules, with_opt: bool) -> tuple:
    """(params, opt_state) ShapeDtypeStructs."""
    defs = T.param_defs(cfg, rules)
    params = abstract_params(defs, jnp.bfloat16, mesh)
    if not with_opt:
        return params, None
    mdt = jnp.bfloat16 if cfg.opt_moment_dtype == "bfloat16" else jnp.float32
    mdefs = defs
    if getattr(cfg, "zero1_moments", False) and rules is not None:
        mdefs = _zero1_defs(defs, rules)
    opt_state = {
        "m": abstract_params(mdefs, mdt, mesh),
        "v": abstract_params(mdefs, mdt, mesh),
        "step": _sds((), jnp.int32, mesh, P()),
    }
    return params, opt_state


def make_optimizer(cfg: ModelConfig) -> AdamW:
    return AdamW(lr=3e-4, moment_dtype=cfg.opt_moment_dtype)


def input_specs(cfg: ModelConfig, shape: Shape, mesh=None, rules=None):
    """ShapeDtypeStruct stand-ins for every model input of a cell — the
    assignment's ``input_specs()`` entry point. Returns a dict for
    train/prefill steps, or the (caches, token, pos) tuple for decode."""
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg, shape, mesh, rules)
    return decode_specs(cfg, shape, mesh, rules)
