"""repro — data version management and machine-actionable reproducibility.

The documented entry point is the Session API:

    import repro
    s = repro.open("/path/to/project", create=True)
    s.run(cmd="python analyze.py", inputs=["in.csv"], outputs=["fig.csv"])
    s.submit_many([repro.RunSpec(script="job.sh", outputs=["out"]), ...])

Only the lightweight core is imported here; the modeling subpackages
(``repro.models``, ``repro.train``, ...) pull in jax and are imported
explicitly by their users.
"""
from .core.dag import Pipeline, PipelineError
from .core.faults import FaultPlan, FaultRule
from .core.remote import NetFaultRule, NetProfile, NetworkFaultModel
from .core.session import Session, open  # noqa: A004 (module-level `open` is the API)
from .core.spec import RunSpec, SpecError

__all__ = [
    "Session", "open", "RunSpec", "SpecError", "Pipeline", "PipelineError",
    "FaultPlan", "FaultRule",
    "NetFaultRule", "NetProfile", "NetworkFaultModel",
]
