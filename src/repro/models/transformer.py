"""One configurable stack for all ten assigned architectures.

Layer heterogeneity (attention / RWKV6 / Mamba mixers, dense / MoE /
dense+MoE FFNs, encoder-decoder, M-RoPE, sliding windows) is expressed as a
repeating block *pattern* (configs/base.py). Weights for each pattern
position are stacked along a leading ``n_repeats`` axis and the stack runs
under ``lax.scan`` — compiled HLO size is O(pattern length), not O(depth),
which keeps 72-layer Jamba and 56-layer Mixtral dry-runs fast.

Three entry points per model: ``forward_train`` (full causal sequence),
``prefill`` (returns decode state + last-position logits), ``decode_step``
(one token against the state). Decode state per pattern position:
  attn  : k/v ring caches  [B, S_cache, KV, Dh]
  rwkv6 : wkv state [B, H, Dh, Dh] (fp32) + token-shift carries [B, D]
  mamba : ssm state [B, Di, St] (fp32) + conv tail [B, K-1, Di]
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import LayerKind, ModelConfig
from ..distributed.sharding import ShardingRules
from . import ssm
from .attention import attention, cache_insert, decode_attention
from .layers import apply_mrope, apply_rope, rmsnorm, swiglu
from .moe import moe_ffn
from .params import ParamDef


# ===================================================================== specs
def _null_spec(*_args) -> P:
    return P()


class _NullRules:
    """Spec provider for unsharded runs (single-device smoke tests)."""

    def __getattr__(self, name):
        return P()

    kv_cache = staticmethod(_null_spec)
    ssm_state = staticmethod(_null_spec)
    w_expert_in = staticmethod(_null_spec)
    w_expert_out = staticmethod(_null_spec)


def _c(x, rules: ShardingRules | None, spec) -> jax.Array:
    """Optional sharding constraint."""
    if rules is None:
        return x
    return rules.constrain(x, spec)


def _use_pallas(cfg: ModelConfig) -> bool:
    """'auto' -> only on real TPU backends; 'on' forces the kernels (they run
    in interpret mode off-TPU); 'off' keeps the pure-jnp blockwise paths
    (the dry-run default — TPU Pallas calls don't lower on the CPU AOT
    backend)."""
    if cfg.use_pallas == "on":
        return True
    if cfg.use_pallas == "off":
        return False
    return jax.default_backend() == "tpu"


# ================================================================ param defs
def _attn_defs(cfg: ModelConfig, r) -> dict:
    H, KV, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    d = {
        "wq": ParamDef((D, H * Dh), r.w_in),
        "wk": ParamDef((D, KV * Dh), r.w_in),
        "wv": ParamDef((D, KV * Dh), r.w_in),
        "wo": ParamDef((H * Dh, D), r.w_out),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((Dh,), P(), "ones")
        d["k_norm"] = ParamDef((Dh,), P(), "ones")
    return d


def _ffn_defs(cfg: ModelConfig, r) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDef((D, F), r.w_in),
        "w3": ParamDef((D, F), r.w_in),
        "w2": ParamDef((F, D), r.w_out),
    }


def _moe_defs(cfg: ModelConfig, r) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    d = {
        "router": ParamDef((D, E), P()),
        "e_w1": ParamDef((E, D, F), r.w_expert_in(E)),
        "e_w3": ParamDef((E, D, F), r.w_expert_in(E)),
        "e_w2": ParamDef((E, F, D), r.w_expert_out(E)),
    }
    if cfg.moe.dense_residual:
        d["dense"] = _ffn_defs(cfg, r)
    return d


def _rwkv_defs(cfg: ModelConfig, r) -> dict:
    H, Dh, D, F = cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.d_ff
    lora = 64
    return {
        "tm_mu": ParamDef((5, D), P(), "zeros"),
        "tm_wr": ParamDef((D, H * Dh), r.w_in),
        "tm_wk": ParamDef((D, H * Dh), r.w_in),
        "tm_wv": ParamDef((D, H * Dh), r.w_in),
        "tm_wg": ParamDef((D, H * Dh), r.w_in),
        "tm_wo": ParamDef((H * Dh, D), r.w_out),
        "tm_w0": ParamDef((D,), P(), "normal", 1.0),
        "tm_w1": ParamDef((D, lora), P(), "zeros"),
        "tm_w2": ParamDef((lora, D), P(), "zeros"),
        "tm_u": ParamDef((H, Dh), P(), "normal", 0.5),
        "tm_ln": ParamDef((H * Dh,), P(), "ones"),
        "cm_mu": ParamDef((2, D), P(), "zeros"),
        "cm_k": ParamDef((D, F), r.w_in),
        "cm_v": ParamDef((F, D), r.w_out),
        "cm_r": ParamDef((D, D), P()),
    }


def _mamba_defs(cfg: ModelConfig, r) -> dict:
    D = cfg.d_model
    Di, St, K = cfg.mamba_d_inner, cfg.mamba.d_state, cfg.mamba.d_conv
    Rdt = max(1, Di // 16)
    tp_name = None if isinstance(r, _NullRules) else r.tp
    tp, tp0 = P(tp_name), P(tp_name, None)  # Di-leading shardings
    return {
        "in_proj": ParamDef((D, 2 * Di), r.w_in),
        "conv_w": ParamDef((Di, K), tp0, "normal", 0.5),
        "conv_b": ParamDef((Di,), tp, "zeros"),
        "x_proj": ParamDef((Di, Rdt + 2 * St), tp0),
        "dt_proj": ParamDef((Rdt, Di), P(None, tp_name)),
        "dt_bias": ParamDef((Di,), tp, "zeros"),
        "a_log": ParamDef((Di, St), tp0, "mamba_a"),
        "d_skip": ParamDef((Di,), tp, "ones"),
        "out_proj": ParamDef((Di, D), r.w_out),
    }


def _block_defs(cfg: ModelConfig, r, kind: LayerKind, cross_attn: bool = False) -> dict:
    D = cfg.d_model
    d: dict[str, Any] = {"ln1": ParamDef((D,), P(), "ones")}
    if kind.mixer == "attn":
        d["attn"] = _attn_defs(cfg, r)
    elif kind.mixer == "rwkv6":
        d["rwkv"] = _rwkv_defs(cfg, r)
        d["ln2"] = ParamDef((D,), P(), "ones")
        return d  # rwkv block = time-mix + channel-mix, no swiglu/moe
    elif kind.mixer == "mamba":
        d["mamba"] = _mamba_defs(cfg, r)
    if cross_attn:
        d["ln_x"] = ParamDef((D,), P(), "ones")
        d["xattn"] = _attn_defs(cfg, r)
    d["ln2"] = ParamDef((D,), P(), "ones")
    d["moe" if kind.moe else "ffn"] = (
        _moe_defs(cfg, r) if kind.moe else _ffn_defs(cfg, r)
    )
    return d


def _stack(defs: dict, n: int) -> dict:
    def one(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, P(None, *tuple(d.spec)), d.init, d.scale)

    return {
        k: one(v) if isinstance(v, ParamDef) else _stack(v, n)
        for k, v in defs.items()
    }


def param_defs(cfg: ModelConfig, rules: ShardingRules | None = None) -> dict:
    r = rules if rules is not None else _NullRules()
    D, Vp = cfg.d_model, cfg.padded_vocab
    defs: dict[str, Any] = {
        "embed": ParamDef((Vp, D), r.embed, "normal", 0.02),
        "final_norm": ParamDef((D,), P(), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, Vp), r.lm_head, "normal", 0.02)
    blocks = {
        f"p{i}": _block_defs(cfg, r, kind, cross_attn=cfg.enc_dec)
        for i, kind in enumerate(cfg.pattern)
    }
    defs["blocks"] = _stack(blocks, cfg.n_repeats)
    if cfg.enc_dec:
        enc_block = _block_defs(cfg, r, LayerKind("attn"), cross_attn=False)
        defs["enc_blocks"] = _stack({"p0": enc_block}, cfg.n_enc_layers)
        defs["enc_final_norm"] = ParamDef((D,), P(), "ones")
    return defs


def cache_defs(
    cfg: ModelConfig, rules, batch: int, cache_len: int, enc_len: int = 0
) -> dict:
    """ParamDef tree matching the decode-state structure that ``prefill``
    produces — used to build ShapeDtypeStructs for the decode dry-run without
    running prefill. Dtypes: KV/conv/shift bf16 (via the dtype argument of
    :func:`abstract_cache`), SSM states fp32 (marked via ``init='fp32'``)."""
    r = rules if rules is not None else _NullRules()
    shardable = batch >= 8
    kv = r.kv_cache(shardable) if rules is not None else P()
    st_spec = r.ssm_state(shardable) if rules is not None else P()
    dp = r._dp() if (rules is not None and batch >= 8) else None
    H, KV, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    Di, St, K = cfg.mamba_d_inner, cfg.mamba.d_state, cfg.mamba.d_conv
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    out = {}
    for i, kind in enumerate(cfg.pattern):
        d: dict[str, Any] = {}
        if kind.mixer == "attn":
            d["k"] = ParamDef((batch, eff_len, KV, Dh), kv)
            d["v"] = ParamDef((batch, eff_len, KV, Dh), kv)
            if cfg.enc_dec:
                d["xk"] = ParamDef((batch, enc_len, KV, Dh), kv)
                d["xv"] = ParamDef((batch, enc_len, KV, Dh), kv)
        elif kind.mixer == "rwkv6":
            d["wkv"] = ParamDef(
                (batch, H, Dh, Dh),
                P(*tuple(st_spec), None, None) if rules is not None else P(),
                "fp32",
            )
            d["shift_t"] = ParamDef((batch, D), P(dp, None) if rules else P())
            d["shift_c"] = ParamDef((batch, D), P(dp, None) if rules else P())
        else:  # mamba
            d["h"] = ParamDef(
                (batch, Di, St),
                P(*tuple(st_spec), None) if rules is not None else P(),
                "fp32",
            )
            d["conv"] = ParamDef(
                (batch, K - 1, Di),
                P(dp, None, r.tp) if rules is not None else P(),
            )
        out[f"p{i}"] = d
    return _stack(out, cfg.n_repeats)


def abstract_cache(cfg: ModelConfig, rules, batch: int, cache_len: int,
                   enc_len: int = 0, mesh=None, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree for the decode state (dry-run input)."""
    from jax.sharding import NamedSharding

    defs = cache_defs(cfg, rules, batch, cache_len, enc_len)

    def walk(node):
        out = {}
        for k, v in node.items():
            if isinstance(v, ParamDef):
                dt = jnp.float32 if v.init == "fp32" else dtype
                if mesh is not None:
                    out[k] = jax.ShapeDtypeStruct(
                        v.shape, dt, sharding=NamedSharding(mesh, v.spec)
                    )
                else:
                    out[k] = jax.ShapeDtypeStruct(v.shape, dt)
            else:
                out[k] = walk(v)
        return out

    return walk(defs)


# ================================================================== context
@dataclass
class Ctx:
    mode: str  # 'train' | 'prefill' | 'decode'
    positions: jax.Array | None = None  # [B, S]
    positions3: jax.Array | None = None  # [3, B, S] (M-RoPE)
    pos: jax.Array | None = None  # scalar, decode
    enc_memory: jax.Array | None = None  # [B, S_enc, D]
    cache_len: int = 0
    causal: bool = True
    batch_shardable: bool = True
    aux: list = field(default_factory=list)


# ================================================================ sub-layers
def _project_qkv(cfg, p_attn, h):
    B, S, _ = h.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ p_attn["wq"]).reshape(B, S, H, Dh)
    k = (h @ p_attn["wk"]).reshape(B, S, KV, Dh)
    v = (h @ p_attn["wv"]).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p_attn["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p_attn["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope(cfg, ctx: Ctx, q, k):
    if not cfg.rope:
        return q, k
    if cfg.mrope_sections:
        pos3 = ctx.positions3
        if pos3 is None:  # decode: same position on all three streams
            pos3 = jnp.broadcast_to(ctx.pos, (3, q.shape[0], q.shape[1])).astype(jnp.int32)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        return q, k
    pos = ctx.positions
    if pos is None:
        pos = jnp.full((q.shape[0], q.shape[1]), ctx.pos, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def _self_attention(cfg, rules, p, x, ctx: Ctx, cache):
    """Returns (mixer_out, new_cache_entries)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(cfg, p["attn"], h)
    q, k = _rope(cfg, ctx, q, k)
    new_cache = {}
    ring = cfg.sliding_window is not None
    if ctx.mode == "decode":
        kc, vc = cache_insert(cache["k"], cache["v"], k, v, ctx.pos)
        out = decode_attention(q, kc, vc, ctx.pos, ring=ring)
        new_cache = {"k": kc, "v": vc}
    elif _use_pallas(cfg) and q.shape[1] % 64 == 0:
        from ..kernels.ops import flash_attention
        out = flash_attention(q, k, v, ctx.causal, cfg.sliding_window)
    else:
        out = attention(
            q, k, v, causal=ctx.causal, window=cfg.sliding_window,
            q_chunk=cfg.attn_q_chunk, unroll_chunks=cfg.attn_unroll_chunks,
        )
        if ctx.mode == "prefill":
            new_cache = _prefill_kv_cache(cfg, rules, ctx, k, v)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["attn"]["wo"]
    return out, new_cache


def _prefill_kv_cache(cfg, rules, ctx: Ctx, k, v):
    B, S, KV, Dh = k.shape
    L = ctx.cache_len
    spec = rules.kv_cache(ctx.batch_shardable) if rules is not None else None

    def build(t):
        buf = jnp.zeros((B, L, KV, Dh), t.dtype)
        if cfg.sliding_window is not None and S > L:
            # ring discipline: token s lives at slot s % L
            tail = t[:, S - L :]
            slots = jnp.mod(jnp.arange(S - L, S), L)
            buf = buf.at[:, slots].set(tail)
        else:
            buf = jax.lax.dynamic_update_slice(buf, t[:, :L], (0, 0, 0, 0))
        return buf if spec is None else rules.constrain(buf, spec)

    return {"k": build(k), "v": build(v)}


def _cross_attention(cfg, rules, p, x, ctx: Ctx, cache):
    h = rmsnorm(x, p["ln_x"], cfg.norm_eps)
    B, S, _ = h.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ p["xattn"]["wq"]).reshape(B, S, H, Dh)
    new_cache = {}
    if ctx.mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
        new_cache = {"xk": xk, "xv": xv}  # static, re-emitted
        out = decode_attention(q, xk, xv, jnp.asarray(xk.shape[1] - 1, jnp.int32))
    else:
        mem = ctx.enc_memory
        xk = (mem @ p["xattn"]["wk"]).reshape(B, -1, KV, Dh)
        xv = (mem @ p["xattn"]["wv"]).reshape(B, -1, KV, Dh)
        out = attention(q, xk, xv, causal=False, q_chunk=cfg.attn_q_chunk)
        if ctx.mode == "prefill":
            new_cache = {"xk": xk, "xv": xv}
    out = out.reshape(B, S, H * Dh) @ p["xattn"]["wo"]
    return out, new_cache


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` as the t=0 predecessor."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _rwkv_block(cfg, rules, p, x, ctx: Ctx, cache):
    """RWKV6 layer: time-mix + channel-mix (its own FFN form)."""
    pr = p["rwkv"]
    H, Dh, D = cfg.n_heads, cfg.head_dim, cfg.d_model
    B, S, _ = x.shape
    decode = ctx.mode == "decode"
    # ---- time mix
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    prev_t = cache["shift_t"][:, None, :] if cache else None
    hh = _shift(h, prev_t)
    mu = pr["tm_mu"]
    def lerp(i):
        return h + (hh - h) * mu[i][None, None, :]
    r = (lerp(0) @ pr["tm_wr"]).reshape(B, S, H, Dh)
    k = (lerp(1) @ pr["tm_wk"]).reshape(B, S, H, Dh)
    v = (lerp(2) @ pr["tm_wv"]).reshape(B, S, H, Dh)
    w_raw = pr["tm_w0"][None, None, :] + jnp.tanh(lerp(3) @ pr["tm_w1"]) @ pr["tm_w2"]
    logw = ssm.rwkv6_decay(w_raw).reshape(B, S, H, Dh)
    g = jax.nn.silu(lerp(4) @ pr["tm_wg"])
    state0 = cache["wkv"] if cache else None
    if decode:
        out1, wkv = ssm.rwkv6_step(
            r[:, 0], k[:, 0], v[:, 0], logw[:, 0], pr["tm_u"], state0
        )
        out = out1[:, None].astype(x.dtype)
    elif _use_pallas(cfg) and S % ssm.RWKV_CHUNK == 0:
        from ..kernels.ops import rwkv6 as rwkv6_kernel
        s0 = state0 if state0 is not None else jnp.zeros(
            (B, H, Dh, Dh), jnp.float32
        )
        out, wkv = rwkv6_kernel(r, k, v, logw.astype(r.dtype), pr["tm_u"], s0)
    else:
        out, wkv = ssm.rwkv6_chunked(r, k, v, logw, pr["tm_u"], state0)
    out = rmsnorm(out.reshape(B, S, H * Dh), pr["tm_ln"], cfg.norm_eps) * g
    x = x + out @ pr["tm_wo"]
    # ---- channel mix
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    prev_c = cache["shift_c"][:, None, :] if cache else None
    hh2 = _shift(h2, prev_c)
    cmu = pr["cm_mu"]
    xk_ = h2 + (hh2 - h2) * cmu[0][None, None, :]
    xr_ = h2 + (hh2 - h2) * cmu[1][None, None, :]
    kk = jnp.square(jax.nn.relu(xk_ @ pr["cm_k"]))
    out2 = jax.nn.sigmoid(xr_ @ pr["cm_r"]) * (kk @ pr["cm_v"])
    x = x + out2
    new_cache = {}
    if ctx.mode in ("prefill", "decode"):
        new_cache = {
            "wkv": wkv,
            "shift_t": h[:, -1, :],
            "shift_c": h2[:, -1, :],
        }
    return x, new_cache


def _mamba_mixer(cfg, rules, p, x, ctx: Ctx, cache):
    pm = p["mamba"]
    Di, St, K = cfg.mamba_d_inner, cfg.mamba.d_state, cfg.mamba.d_conv
    Rdt = max(1, Di // 16)
    B, S, _ = x.shape
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    xz = h @ pm["in_proj"]  # [B, S, 2Di]
    xr, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache else None
    xr_conv = ssm.mamba_conv(xr, pm["conv_w"], pm["conv_b"], conv_state)
    u = jax.nn.silu(xr_conv)
    dbl = u @ pm["x_proj"]  # [B, S, Rdt + 2 St]
    dt_r = dbl[..., :Rdt]
    B_ = dbl[..., Rdt : Rdt + St].astype(jnp.float32)
    C_ = dbl[..., Rdt + St :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_r @ pm["dt_proj"] + pm["dt_bias"][None, None, :])
    A = -jnp.exp(pm["a_log"].astype(jnp.float32))
    h0 = cache["h"] if cache else None
    if ctx.mode == "decode":
        y1, hs = ssm.mamba_step(u[:, 0], dt[:, 0], A, B_[:, 0], C_[:, 0], h0)
        y = y1[:, None].astype(x.dtype)
    elif _use_pallas(cfg) and S % 64 == 0 and Di % 64 == 0:
        from ..kernels.ops import mamba_scan
        h00 = h0 if h0 is not None else jnp.zeros((B, Di, St), jnp.float32)
        y, hs = mamba_scan(u, dt, A, B_.astype(u.dtype), C_.astype(u.dtype), h00)
    else:
        y, hs = ssm.mamba_scan_chunked(u, dt, A, B_, C_, h0)
    y = y + pm["d_skip"][None, None, :] * u
    y = y * jax.nn.silu(z)
    out = y @ pm["out_proj"]
    new_cache = {}
    if ctx.mode in ("prefill", "decode"):
        if ctx.mode == "decode":
            new_conv = jnp.concatenate(
                [cache["conv"][:, 1:], xr[:, -1:, :].astype(cache["conv"].dtype)], axis=1
            )
        else:
            pad = jnp.zeros((B, max(0, K - 1 - S), Di), xr.dtype)
            new_conv = jnp.concatenate([pad, xr[:, -(K - 1):, :]], axis=1)
        new_cache = {"h": hs, "conv": new_conv}
    return out, new_cache


def _ffn_or_moe(cfg, rules, kind: LayerKind, p, x, ctx: Ctx):
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind.moe:
        pm = p["moe"]
        out, aux = moe_ffn(
            h, pm["router"], pm["e_w1"], pm["e_w3"], pm["e_w2"], cfg.moe
        )
        if cfg.moe.dense_residual:
            d = pm["dense"]
            out = out + swiglu(h, d["w1"], d["w3"], d["w2"])
        return out, aux
    f = p["ffn"]
    return swiglu(h, f["w1"], f["w3"], f["w2"]), jnp.zeros((), jnp.float32)


def apply_block(cfg, rules, kind: LayerKind, p, x, ctx: Ctx, cache):
    """One pattern-position layer. Returns (x, new_cache, aux_loss)."""
    if kind.mixer == "rwkv6":
        x, new_cache = _rwkv_block(cfg, rules, p, x, ctx, cache)
        return _c(x, rules, rules.residual if rules else None), new_cache, jnp.zeros((), jnp.float32)
    if kind.mixer == "attn":
        mix, new_cache = _self_attention(cfg, rules, p, x, ctx, cache)
    else:
        mix, new_cache = _mamba_mixer(cfg, rules, p, x, ctx, cache)
    x = x + mix
    if cfg.enc_dec and "xattn" in p:
        xmix, xcache = _cross_attention(cfg, rules, p, x, ctx, cache)
        x = x + xmix
        new_cache = {**new_cache, **xcache}
    ffn_out, aux = _ffn_or_moe(cfg, rules, kind, p, x, ctx)
    x = x + ffn_out
    x = _c(x, rules, rules.residual if rules else None)
    return x, new_cache, aux


# ================================================================ stacks
def _run_blocks(cfg, rules, blocks, x, ctx: Ctx, caches=None, pattern=None):
    """Scan the stacked pattern blocks. Returns (x, new_caches, aux_total)."""
    pattern = pattern if pattern is not None else cfg.pattern

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_cache = xs
        new_cache = {}
        for i, kind in enumerate(pattern):
            key = f"p{i}"
            c_in = layer_cache[key] if layer_cache is not None else None
            x, nc, a = apply_block(cfg, rules, kind, layer_params[key], x, ctx, c_in)
            aux = aux + a
            new_cache[key] = nc
        return (x, aux), new_cache if new_cache and any(new_cache.values()) else None

    fn = jax.checkpoint(body) if (cfg.remat and ctx.mode == "train") else body
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                            (blocks, caches))
        return x, new_caches, aux
    # unrolled path (debugging + dry-run cost modules)
    n = jax.tree.leaves(blocks)[0].shape[0]
    aux = jnp.zeros((), jnp.float32)
    outs = []
    for rep in range(n):
        lp = jax.tree.map(lambda t: t[rep], blocks)
        lc = jax.tree.map(lambda t: t[rep], caches) if caches is not None else None
        (x, aux), nc = fn((x, aux), (lp, lc))
        outs.append(nc)
    new_caches = (
        jax.tree.map(lambda *ts: jnp.stack(ts), *outs) if outs and outs[0] else None
    )
    return x, new_caches, aux


def _embed_inputs(cfg, params, batch) -> jax.Array:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.vision_len_ratio and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)  # [B, Sv, D]
        x = jnp.concatenate([ve, x[:, ve.shape[1]:, :]], axis=1)
    return x


def _encode(cfg, rules, params, batch, ctx_mode: str):
    """Run the encoder stack over precomputed frame embeddings."""
    enc_x = batch["encoder_embeds"].astype(params["enc_final_norm"].dtype)
    ectx = Ctx(mode="train", causal=False)
    enc_x, _, _ = _run_blocks(
        cfg, rules, params["enc_blocks"], enc_x, ectx,
        caches=None, pattern=(LayerKind("attn"),),
    )
    return rmsnorm(enc_x, params["enc_final_norm"], cfg.norm_eps)


def _logits(cfg, params, x) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


# ================================================================ entry points
def forward_train(cfg: ModelConfig, rules, params, batch) -> tuple[jax.Array, jax.Array]:
    """Full-sequence causal forward. Returns (logits [B,S,Vp], aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = Ctx(mode="train", positions=positions,
              positions3=batch.get("positions3"))
    if cfg.enc_dec:
        ctx.enc_memory = _encode(cfg, rules, params, batch, "train")
    x = _c(x, rules, rules.residual if rules else None)
    x, _, aux = _run_blocks(cfg, rules, params["blocks"], x, ctx)
    return _logits(cfg, params, x), aux


def prefill(cfg: ModelConfig, rules, params, batch, cache_len: int):
    """Process a full prompt; returns (state, last-token logits [B,Vp])."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    eff_cache = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    ctx = Ctx(mode="prefill", positions=positions,
              positions3=batch.get("positions3"), cache_len=eff_cache,
              batch_shardable=B >= 8)
    if cfg.enc_dec:
        ctx.enc_memory = _encode(cfg, rules, params, batch, "prefill")
    x = _c(x, rules, rules.residual if rules else None)
    x, caches, _ = _run_blocks(cfg, rules, params["blocks"], x, ctx)
    logits = _logits(cfg, params, x[:, -1:, :])[:, 0]
    return caches, logits


def decode_step(cfg: ModelConfig, rules, params, caches, token, pos):
    """One decode step. token [B,1] int32; pos scalar int32 (position of the
    new token). Returns (logits [B,Vp], new_caches)."""
    x = jnp.take(params["embed"], token, axis=0)
    ctx = Ctx(mode="decode", pos=pos,
              batch_shardable=token.shape[0] >= 8)
    x, new_caches, _ = _run_blocks(cfg, rules, params["blocks"], x, ctx, caches)
    return _logits(cfg, params, x)[:, 0], new_caches
