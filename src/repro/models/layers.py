"""Shared neural building blocks (pure JAX, dtype-disciplined).

Norms and softmax statistics accumulate in fp32; matmuls run in the model
compute dtype (bf16 on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * scale.astype(jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x@w1) * (x@w3)) @ w2."""
    gate = jax.nn.silu(x @ w1)
    return (gate * (x @ w3)) @ w2


# ---------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary half-dims: [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] (int)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary half-dims are split into sections
    (temporal / height / width), each rotated by its own position stream.

    x: [B, S, H, Dh]; positions3: [3, B, S]; sum(sections) == Dh // 2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    # pick the position stream per frequency index
    section_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # [half] in {0,1,2}
    pos = positions3[section_ids, :, :]  # [half, B, S]
    angles = jnp.einsum("hbs,h->bsh", pos.astype(jnp.float32), freqs)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- loss
def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token cross-entropy. Uses a one-hot contraction so vocab-sharded
    logits never gather (see distributed/sharding.py)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
