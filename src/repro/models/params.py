"""Parameter definition trees: shapes + shardings + init, in one walk.

A model is declared as a nested dict of :class:`ParamDef`. From the same
tree we derive (a) materialized parameters for CPU smoke tests / real
training, (b) ``jax.ShapeDtypeStruct`` stand-ins with ``NamedSharding``
attached for the multi-pod dry-run (no allocation), and (c) the
``in_shardings`` pytree for ``jax.jit``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P  # logical PartitionSpec (ignored when no mesh)
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; None -> 1/sqrt(fan_in)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_paths(defs: dict, prefix: str = "") -> list[tuple[str, ParamDef]]:
    out = []
    for name in sorted(defs):
        node = defs[name]
        path = f"{prefix}/{name}"
        if _is_def(node):
            out.append((path, node))
        else:
            out.extend(tree_paths(node, path))
    return out


def _map_defs(defs, fn):
    if _is_def(defs):
        raise TypeError("expected a dict tree")
    return {
        name: fn(node) if _is_def(node) else _map_defs(node, fn)
        for name, node in defs.items()
    }


def _init_one(path: str, d: ParamDef, seed: int, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "mamba_a":
        # S4D-real init: A_log[d, n] = log(n + 1), broadcast over channels
        st = d.shape[-1]
        row = jnp.log(jnp.arange(1, st + 1, dtype=jnp.float32))
        return jnp.broadcast_to(row, d.shape).astype(dtype)
    # deterministic per-path key
    digest = hashlib.sha256(f"{seed}:{path}".encode()).digest()
    key = jax.random.PRNGKey(int.from_bytes(digest[:4], "big"))
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    scale = d.scale if d.scale is not None else fan_in**-0.5
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def init_params(defs: dict, seed: int, dtype=jnp.bfloat16) -> dict:
    """Materialize parameters (smoke tests / real training)."""

    def walk(node, prefix):
        return {
            name: _init_one(f"{prefix}/{name}", child, seed, dtype)
            if _is_def(child)
            else walk(child, f"{prefix}/{name}")
            for name, child in node.items()
        }

    return walk(defs, "")


def abstract_params(defs: dict, dtype, mesh=None) -> dict:
    """ShapeDtypeStruct tree (with shardings when a mesh is given) — the
    dry-run path: weak-type-correct, shardable, no device allocation."""

    def one(d: ParamDef):
        if mesh is not None:
            return jax.ShapeDtypeStruct(
                d.shape, dtype, sharding=NamedSharding(mesh, d.spec)
            )
        return jax.ShapeDtypeStruct(d.shape, dtype)

    return _map_defs(defs, one)


def param_specs(defs: dict) -> dict:
    return _map_defs(defs, lambda d: d.spec)


def param_shardings(defs: dict, mesh) -> dict:
    return _map_defs(defs, lambda d: NamedSharding(mesh, d.spec))


def param_count(defs: dict) -> int:
    total = 0
    for _, d in tree_paths(defs):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
