"""Attention-free mixers: RWKV6 (Finch) time-mix and Mamba selective scan.

Both provide three execution paths:
  - ``*_naive``  : step-by-step ``lax.scan`` over time — the oracle, used for
                   tests and as the decode single-step math,
  - ``*_chunked``: chunk-parallel formulation used by train/prefill (pure
                   JAX; the Pallas kernels in ``repro.kernels`` mirror this
                   blocking with VMEM tiles),
  - ``*_step``   : single-token decode update.

Numerics: RWKV6's per-channel log-decay is clamped to ``-MAX_DECAY`` per step
and chunks are kept short (16) so ``exp(±Σ log w)`` stays inside fp32 range —
the clamp is applied identically in every path, so they agree bitwise-ish
(allclose at fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MAX_DECAY = 4.0  # clamp on exp(w_raw): decay factor >= exp(-4) per step
RWKV_CHUNK = 16
MAMBA_CHUNK = 256


# ====================================================================== RWKV6
def rwkv6_decay(w_raw: jax.Array) -> jax.Array:
    """Raw decay projection -> log decay in [-MAX_DECAY, 0)."""
    return -jnp.minimum(jnp.exp(w_raw.astype(jnp.float32)), MAX_DECAY)


def rwkv6_naive(
    r: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # [B, S, H, Dh] log decay (negative)
    u: jax.Array,  # [H, Dh] bonus
    state0: jax.Array | None = None,  # [B, H, Dh, Dh]
) -> tuple[jax.Array, jax.Array]:
    """Oracle: out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ); S_t = diag(w_t) S_{t-1} + k_t v_tᵀ."""
    b, s, h, dh = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp  # [B, H, Dh]
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,Dh,Dh]
        out = jnp.einsum("bhd,bhde->bhe", r_t, S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., :, None] * S + kv
        return S, out

    seq = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, logw)
    )
    state, out = jax.lax.scan(step, state0, seq)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state


def rwkv6_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
    u: jax.Array, state0: jax.Array | None = None, chunk: int = RWKV_CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV: intra-chunk via masked score matrix, cross-chunk
    via the carried state. Matches :func:`rwkv6_naive` to fp32 tolerance."""
    b, s, h, dh = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    if s % chunk != 0:  # fall back (decode tails etc.)
        return rwkv6_naive(r, k, v, logw, u, state0)
    n = s // chunk

    def to_chunks(t):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(b, n, chunk, h, dh), 1, 0
        )  # [n, B, L, H, Dh]

    rs, ks, vs, lws = map(to_chunks, (r, k, v, logw))

    def chunk_fn(S_in, inp):
        r_c, k_c, v_c, lw_c = inp  # [B, L, H, Dh]
        la = jnp.cumsum(lw_c, axis=1)  # inclusive log-decay products
        q_ = r_c * jnp.exp(la - lw_c)  # r_t * A_{t-1}
        k_ = k_c * jnp.exp(-la)  # k_s / A_s
        scores = jnp.einsum("blhd,bmhd->bhlm", q_, k_)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # s < t strictly
        diag = jnp.einsum("blhd,hd,blhd->bhl", r_c, u.astype(jnp.float32), k_c)
        scores = scores * tri[None, None]
        scores = scores + jnp.einsum("bhl,lm->bhlm", diag, jnp.eye(chunk, dtype=jnp.float32))
        intra = jnp.einsum("bhlm,bmhd->blhd", scores, v_c)
        cross = jnp.einsum("blhd,bhde->blhe", q_, S_in)
        out = intra + cross
        la_last = la[:, -1]  # [B, H, Dh]
        kd = k_c * jnp.exp(la_last[:, None] - la)
        S_out = S_in * jnp.exp(la_last)[..., None] + jnp.einsum(
            "blhd,blhe->bhde", kd, v_c
        )
        return S_out, out

    state, outs = jax.lax.scan(jax.checkpoint(chunk_fn), state0, (rs, ks, vs, lws))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)
    return out.astype(r.dtype), state


def rwkv6_step(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
    u: jax.Array, state: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single-token decode. Inputs [B, H, Dh]; state [B, H, Dh, Dh]."""
    r, k, v, logw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhd,bhde->bhe", r, state + u[None, :, :, None] * kv)
    new_state = jnp.exp(logw)[..., :, None] * state + kv
    return out, new_state


# ====================================================================== Mamba
def mamba_conv(
    x: jax.Array,  # [B, S, Di]
    conv_w: jax.Array,  # [Di, K]
    conv_b: jax.Array,  # [Di]
    conv_state: jax.Array | None = None,  # [B, K-1, Di] trailing context
) -> jax.Array:
    """Depthwise causal conv along time via K shifted adds."""
    k = conv_w.shape[-1]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # [B, S+K-1, Di]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * conv_w[:, i][None, None, :]
    return out + conv_b[None, None, :]


def mamba_scan_naive(
    u: jax.Array,  # [B, S, Di]  (post-conv, post-silu input)
    dt: jax.Array,  # [B, S, Di]
    A: jax.Array,  # [Di, St]
    B_: jax.Array,  # [B, S, St]
    C_: jax.Array,  # [B, S, St]
    h0: jax.Array | None = None,  # [B, Di, St]
) -> tuple[jax.Array, jax.Array]:
    """Oracle selective scan: h_t = exp(dt A) h_{t-1} + dt·B_t·u_t; y_t = C_t·h_t."""
    b, s, di = u.shape
    st = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, di, st), jnp.float32)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t[..., None] * A[None])  # [B, Di, St]
        h = a * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    seq = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (u, dt, B_, C_)
    )
    h, ys = jax.lax.scan(step, h0, seq)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype), h


def mamba_scan_chunked(
    u: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array, C_: jax.Array,
    h0: jax.Array | None = None, chunk: int = MAMBA_CHUNK,
) -> tuple[jax.Array, jax.Array]:
    """Chunked scan: outer ``lax.scan`` over chunks (rematerialized), inner
    sequential scan within a chunk. Keeps backward-pass residuals at
    O(S/chunk · state) instead of O(S · state)."""
    b, s, di = u.shape
    if s % chunk != 0 or s <= chunk:
        return mamba_scan_naive(u, dt, A, B_, C_, h0)
    st = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, di, st), jnp.float32)
    n = s // chunk

    def to_chunks(t, width):
        return jnp.moveaxis(
            t.astype(jnp.float32).reshape(b, n, chunk, width), 1, 0
        )

    us, dts = to_chunks(u, di), to_chunks(dt, di)
    bs, cs = to_chunks(B_, st), to_chunks(C_, st)

    def chunk_fn(h, inp):
        u_c, dt_c, b_c, c_c = inp

        def step(hh, s_inp):
            u_t, dt_t, b_t, c_t = s_inp
            a = jnp.exp(dt_t[..., None] * A[None])
            hh = a * hh + (dt_t * u_t)[..., None] * b_t[:, None, :]
            y = jnp.einsum("bds,bs->bd", hh, c_t)
            return hh, y

        seq = tuple(jnp.moveaxis(t, 1, 0) for t in (u_c, dt_c, b_c, c_c))
        h, ys = jax.lax.scan(step, h, seq)
        return h, jnp.moveaxis(ys, 0, 1)

    h, ys = jax.lax.scan(jax.checkpoint(chunk_fn), h0, (us, dts, bs, cs))
    out = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    return out.astype(u.dtype), h


def mamba_step(
    u_t: jax.Array,  # [B, Di]
    dt_t: jax.Array,  # [B, Di]
    A: jax.Array,  # [Di, St]
    b_t: jax.Array,  # [B, St]
    c_t: jax.Array,  # [B, St]
    h: jax.Array,  # [B, Di, St]
) -> tuple[jax.Array, jax.Array]:
    u_t, dt_t, b_t, c_t = (t.astype(jnp.float32) for t in (u_t, dt_t, b_t, c_t))
    a = jnp.exp(dt_t[..., None] * A[None])
    h = a * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_t)
    return y.astype(u_t.dtype), h
