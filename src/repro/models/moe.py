"""Mixture-of-Experts FFN: top-k routing with capacity-based einsum dispatch.

The dispatch/combine-tensor formulation (Mesh-TensorFlow / flaxformer style)
is the GSPMD-friendly reference: it lowers to dense einsums whose sharding
follows the expert-weight annotations (experts over "data", ff over "model";
see distributed/sharding.py). Tokens beyond an expert's capacity are dropped
(standard top-k MoE semantics); the auxiliary load-balancing loss keeps the
router spread out.

Arctic's "dense residual" variant (128-expert MoE in parallel with a dense
FFN) is handled at the transformer level by running both and summing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig


def router_topk(
    x: jax.Array, w_router: jax.Array, cfg: MoEConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates [B,S,k], expert_idx [B,S,k], aux_loss scalar)."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), w_router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balancing loss (Switch-style): E * sum_e f_e * p_e
    e = w_router.shape[-1]
    assign = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)  # top-1 assignment
    f = jnp.mean(assign, axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f * p)
    return gates, idx, aux


def moe_ffn(
    x: jax.Array,  # [B, S, D]
    w_router: jax.Array,  # [D, E]
    w1: jax.Array,  # [E, D, F]
    w3: jax.Array,  # [E, D, F]
    w2: jax.Array,  # [E, F, D]
    cfg: MoEConfig,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux_loss)."""
    b, s, d = x.shape
    e, k = w1.shape[0], cfg.top_k
    gates, idx, aux = router_topk(x, w_router, cfg)

    capacity = max(1, int(cfg.capacity_factor * s * k / e))
    # expert one-hot per (token, k-slot): [B, S, k, E]
    mask = jax.nn.one_hot(idx, e, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue, counted over
    # the flattened (S, k) order: [B, S*k, E]
    mask_flat = mask.reshape(b, s * k, e)
    pos_in_expert = jnp.cumsum(mask_flat, axis=1) * mask_flat - 1.0
    within = (pos_in_expert < capacity) & (pos_in_expert >= 0)
    # dispatch one-hot over capacity slots: [B, S*k, E, C]
    dispatch_flat = jax.nn.one_hot(
        jnp.where(within, pos_in_expert, -1).astype(jnp.int32), capacity, dtype=x.dtype
    ) * within[..., None].astype(x.dtype)
    dispatch = dispatch_flat.reshape(b, s, k, e, capacity)
    combine = jnp.einsum("bskec,bsk->bsec", dispatch.astype(jnp.float32),
                         gates).astype(x.dtype)
    dispatch = jnp.sum(dispatch, axis=2)  # [B, S, E, C]

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)  # [E, B, C, D]
    gate_h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", expert_in, w1))
    lin_h = jnp.einsum("ebcd,edf->ebcf", expert_in, w3)
    y = jnp.einsum("ebcf,efd->ebcd", gate_h * lin_h, w2)  # [E, B, C, D]
    out = jnp.einsum("bsec,ebcd->bsd", combine, y)
    return out, aux.astype(jnp.float32)
