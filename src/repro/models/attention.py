"""Attention: blockwise (flash-style) GQA with causal/sliding-window masking,
plus single-token decode attention against a KV cache.

The training/prefill path chunks queries and recomputes per-chunk under
``jax.checkpoint`` — O(S·chunk) live score memory instead of O(S²), which is
the flash-attention memory behaviour expressed in pure JAX (the Pallas TPU
kernel in ``repro.kernels.flash_attention`` implements the same math with
explicit VMEM tiling; ``use_pallas`` selects it on TPU backends).

GQA is computed in grouped form [B, KV, G, ...] so repeated K/V heads are
never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _grouped(q: jax.Array, n_kv: int) -> jax.Array:
    """[B, S, H, Dh] -> [B, S, KV, G, Dh]."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _attn_chunk(
    q: jax.Array,  # [B, qc, KV, G, Dh]
    k: jax.Array,  # [B, Sk, KV, Dh]
    v: jax.Array,  # [B, Sk, KV, Dh]
    q_pos: jax.Array | None,  # [qc] global query positions (None = no mask)
    k_pos: jax.Array | None,  # [Sk]
    window: int | None,
) -> jax.Array:
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if q_pos is not None:
        valid = k_pos[None, :] <= q_pos[:, None]  # causal
        if window is not None:
            valid &= k_pos[None, :] > (q_pos[:, None] - window)
        scores = jnp.where(valid[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def attention(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, KV, Dh]
    v: jax.Array,  # [B, Sk, KV, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 1024,
    unroll_chunks: bool = False,
) -> jax.Array:
    """Full-sequence attention, query-chunked when Sq > q_chunk.

    ``unroll_chunks`` replaces the chunk loop with a static python loop so
    XLA's cost analysis sees every chunk (used by the dry-run cost modules;
    the runtime default keeps the loop for O(1) HLO size)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    qg = _grouped(q, kv)
    q_pos = jnp.arange(sq, dtype=jnp.int32) if causal else None
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32) if causal else None
    if sq <= q_chunk or sq % q_chunk != 0:
        out = _attn_chunk(qg, k, v, q_pos, k_pos, window)
        return out.reshape(b, sq, h, d)

    n_chunks = sq // q_chunk
    qs = qg.reshape(b, n_chunks, q_chunk, kv, h // kv, d)
    qs = jnp.moveaxis(qs, 1, 0)  # [n_chunks, B, qc, KV, G, Dh]
    pos = (
        q_pos.reshape(n_chunks, q_chunk)
        if q_pos is not None
        else jnp.zeros((n_chunks, q_chunk), jnp.int32)
    )

    @jax.checkpoint
    def one_chunk(args):
        q_c, pos_c = args
        return _attn_chunk(q_c, k, v, pos_c if causal else None, k_pos, window)

    if unroll_chunks:
        outs = [one_chunk((qs[i], pos[i])) for i in range(n_chunks)]
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(one_chunk, (qs, pos))  # [n_chunks, B, qc, KV, G, Dh]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)
    return out


def decode_attention(
    q: jax.Array,  # [B, 1, H, Dh]
    k_cache: jax.Array,  # [B, S_cache, KV, Dh]
    v_cache: jax.Array,  # [B, S_cache, KV, Dh]
    pos: jax.Array,  # scalar int32: position of the new token
    *,
    ring: bool = False,
) -> jax.Array:
    """One-token attention against a cache. ``ring=True`` marks a sliding-
    window ring buffer (every slot is valid once the buffer wrapped; RoPE was
    applied at insert so slot order is irrelevant to the math)."""
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    s = k_cache.shape[1]
    qg = _grouped(q, kv)  # [B, 1, KV, G, Dh]
    scale = d**-0.5
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    idx = jnp.arange(s, dtype=jnp.int32)
    n_valid = jnp.minimum(pos + 1, s) if ring else pos + 1
    valid = idx[None, :] < n_valid
    scores = jnp.where(valid[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, h, d)


def cache_insert(
    k_cache: jax.Array, v_cache: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Write one token's K/V at ``pos`` (mod cache length = ring semantics)."""
    slot = jnp.mod(pos, k_cache.shape[1])
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    return k_cache, v_cache
