from . import attention, layers, moe, params, ssm, transformer
from .transformer import decode_step, forward_train, param_defs, prefill

__all__ = [
    "attention", "layers", "moe", "params", "ssm", "transformer",
    "decode_step", "forward_train", "param_defs", "prefill",
]
