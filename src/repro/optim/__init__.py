from .adamw import AdamW, clip_by_global_norm, cosine_schedule, global_norm
from .compression import compress_int8, decompress_int8

__all__ = [
    "AdamW", "clip_by_global_norm", "cosine_schedule", "global_norm",
    "compress_int8", "decompress_int8",
]
