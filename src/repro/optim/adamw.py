"""AdamW with ZeRO-sharded moments (moments inherit parameter shardings).

fp32 update math over bf16 parameters; moment dtype is configurable —
``bfloat16`` for the 400B-class configs (arctic, jamba) where fp32 moments
alone would exceed per-chip HBM on the 256-chip pod (see DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    moment_dtype: str = "float32"

    def _mdt(self):
        return jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32

    def init(self, params) -> dict:
        mdt = self._mdt()
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        mdt = self._mdt()

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
            p32 = p.astype(jnp.float32)
            wd = self.weight_decay if p.ndim >= 2 else 0.0
            new_p = p32 - lr * (mhat / (jnp.sqrt(vhat) + self.eps) + wd * p32)
            return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32),
        }
