"""Opt-in int8 gradient compression with error feedback.

For cross-pod data-parallel all-reduces the pod axis rides DCI links an order
of magnitude slower than intra-pod ICI; quantizing gradient blocks to int8
with per-block scales cuts that traffic 2x vs bf16 (4x vs fp32) at the cost
of quantization noise, which the error-feedback residual re-injects next
step (Seide et al.-style EF).

Usage: wrap the gradient tree before the optimizer when ``compress_grads``
is enabled in the train loop; the residual state is carried like optimizer
state. The compression is simulated faithfully (quantize -> dequantize) so
numerics match what real compressed collectives would produce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor-row int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    flat = x32.reshape(x32.shape[0] if x32.ndim > 1 else 1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def ef_compress_tree(grads, residual):
    """Error-feedback compression over a gradient tree.

    Returns (dequantized grads as would arrive post-allreduce, new residual).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = compress_int8(g32)
        deq = decompress_int8(q, s, g32.shape)
        return deq.astype(g.dtype), g32 - deq

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(one, grads, residual)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r
