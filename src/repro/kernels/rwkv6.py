"""RWKV6 chunked WKV recurrence as a Pallas TPU kernel.

Blocking: grid ``(B, H, S/L)`` with the chunk axis innermost (sequential on
TPU); the [Dh, Dh] WKV state lives in fp32 VMEM scratch carried across
chunks and re-initialized per (batch, head). Within a chunk the recurrence
is closed-form: an L x L masked score matrix (intra-chunk), a state
read-out (cross-chunk), and a rank-L state update — three small MXU matmuls
instead of L sequential vector ops, which is the TPU-native reshaping of the
RWKV CUDA kernel's per-timestep loop.

Chunks are short (L=16) and decays are clamped (see models/ssm.py MAX_DECAY)
so the exp(±cumsum(log w)) factors stay inside fp32 range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, lw_ref,  # [1, 1, L, Dh]
    u_ref,  # [1, Dh]
    s0_ref,  # [1, 1, Dh, Dh]
    o_ref,  # [1, 1, L, Dh]
    sout_ref,  # [1, 1, Dh, Dh]
    state_scr,  # VMEM [Dh, Dh] fp32
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # [L, Dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # [Dh]

    la = jnp.cumsum(lw, axis=0)  # [L, Dh] inclusive log-decay
    q_ = r * jnp.exp(la - lw)  # r_t * A_{t-1}
    k_ = k * jnp.exp(-la)  # k_s / A_s
    scores = jax.lax.dot_general(
        q_, k_, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, L]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(cols < rows, scores, 0.0)  # strictly lower triangular
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # bonus term, [L]
    scores = scores + jnp.diag(diag)
    intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, Dh]
    S = state_scr[...]
    cross = jax.lax.dot_general(
        q_, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, Dh_v]
    o_ref[0, 0, :, :] = (intra + cross).astype(o_ref.dtype)

    la_last = la[-1:, :]  # [1, Dh]
    kd = k * jnp.exp(la_last - la)  # [L, Dh]
    state_scr[...] = S * jnp.exp(la_last).T + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ic == n_chunks - 1)
    def _done():
        sout_ref[0, 0, :, :] = state_scr[...]


def rwkv6_bhsd(
    r: jax.Array,  # [B, H, S, Dh]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # [B, H, S, Dh]
    u: jax.Array,  # [H, Dh]
    state0: jax.Array,  # [B, H, Dh, Dh] fp32
    *,
    chunk: int = 16,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, h, s, d = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, n_chunks=nc)
    out, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, ic: (h_, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, ic: (b_, h_, ic, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, logw, u, state0)
    return out, state
