"""Pallas TPU kernels for the perf-critical mixers.

Each kernel ships three layers (see EXAMPLE.md convention):
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling,
  ops.py    — jit-able wrappers (layout + backend dispatch + custom_vjp),
  ref.py    — pure-jnp oracles the tests sweep against.
"""
from . import ops, ref
from .ops import flash_attention, mamba_scan, rwkv6

__all__ = ["ops", "ref", "flash_attention", "mamba_scan", "rwkv6"]
