"""Pure-jnp oracles for every Pallas kernel (self-contained, no model deps).

These are the ground truth the kernel tests sweep against; they are also the
math-identical fallbacks the model uses on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, KV, Dh]
    v: jax.Array,  # [B, Sk, KV, Dh]
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Direct softmax attention with GQA head repetition."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d**-0.5)
    if causal:
        rows = jnp.arange(sq)[:, None]
        cols = jnp.arange(k.shape[1])[None, :]
        mask = cols <= rows
        if window is not None:
            mask &= cols > rows - window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_ref(
    r: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # [B, S, H, Dh] (negative log decays)
    u: jax.Array,  # [H, Dh]
    state0: jax.Array | None = None,  # [B, H, Dh, Dh] fp32
) -> tuple[jax.Array, jax.Array]:
    """Sequential WKV recurrence:
    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ); S_t = diag(w_t) S_{t-1} + k_t v_tᵀ."""
    b, s, h, dh = r.shape
    S = (
        jnp.zeros((b, h, dh, dh), jnp.float32) if state0 is None
        else state0.astype(jnp.float32)
    )

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", r_t, S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_t)[..., :, None] * S + kv
        return S, out

    seq = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, logw))
    S, out = jax.lax.scan(step, S, seq)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), S


def mamba_ref(
    u: jax.Array,  # [B, S, Di]
    dt: jax.Array,  # [B, S, Di]
    A: jax.Array,  # [Di, St]
    B_: jax.Array,  # [B, S, St]
    C_: jax.Array,  # [B, S, St]
    h0: jax.Array | None = None,  # [B, Di, St] fp32
) -> tuple[jax.Array, jax.Array]:
    """Sequential selective scan:
    h_t = exp(dt_t A) h_{t-1} + (dt_t u_t) B_t; y_t = h_t · C_t."""
    b, s, di = u.shape
    st = A.shape[-1]
    h = jnp.zeros((b, di, st), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t[..., None] * A[None].astype(jnp.float32))
        h = a * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        return h, jnp.einsum("bds,bs->bd", h, c_t)

    seq = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (u, dt, B_, C_))
    h, ys = jax.lax.scan(step, h, seq)
    return jnp.moveaxis(ys, 0, 1).astype(u.dtype), h
