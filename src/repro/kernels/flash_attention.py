"""Flash attention as a Pallas TPU kernel (forward).

TPU-native blocking (not a CUDA port): the grid is ``(B, H, Sq/bq, Sk/bk)``
with the KV-block axis innermost — TPU grids execute sequentially over the
last dimension, so the online-softmax running statistics (m, l) and the
output accumulator live in VMEM scratch that persists across KV blocks and
is re-initialized when a new query block begins. Q/K/V tiles stream
HBM→VMEM via BlockSpecs; the MXU sees [bq, Dh] x [Dh, bk] matmuls with Dh
padded to the 128-lane register width.

GQA is handled in the K/V index_map (query head h reads KV head ``h // G``)
so repeated heads are never materialized in HBM. Causal and sliding-window
masks are applied from block-relative iotas.

Scratch layout follows the official JAX flash kernel convention: m/l are
[bq, 128] with lane-broadcast values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref,  # [1, 1, bq|bk, Dh]
    o_ref,  # [1, 1, bq, Dh]
    m_scr, l_scr, acc_scr,  # [bq, 128], [bq, 128], [bq, Dh]
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    nk: int,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, Dh]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, Dh]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0:1]  # [bq, 1]
    l_prev = l_scr[:, 0:1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # fully-masked blocks: keep p exactly 0 (avoids exp(NEG-NEG)=1 poison)
    p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new), 0.0)  # [bq, bk]
    alpha = jnp.where(m_prev > 0.5 * NEG_INF, jnp.exp(m_prev - m_new), 1.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _done():
        l = l_scr[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # [B, H, Sq, Dh]
    k: jax.Array,  # [B, KV, Sk, Dh]
    v: jax.Array,  # [B, KV, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=d**-0.5, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
