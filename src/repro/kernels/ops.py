"""Jitted public wrappers around the Pallas kernels.

Layout adaptation ([B,S,H,Dh] model convention <-> [B,H,S,Dh] kernel
convention), backend dispatch (``interpret=True`` automatically off-TPU so
the kernels execute correctly on CPU), and custom_vjp wiring: forward runs
the kernel, backward rematerializes through the pure-jnp reference — exact
same math, so gradients are correct while the hot forward path uses the
hand-tiled kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_bhsd
from .mamba import mamba_scan_bsd
from .rwkv6 import rwkv6_bhsd


def _interpret(flag: bool | None) -> bool:
    if flag is not None:
        return flag
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ flash attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, window=None, interpret=None):
    """q [B,Sq,H,Dh]; k/v [B,Sk,KV,Dh] -> [B,Sq,H,Dh]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    bq = _pick_block(q.shape[1])
    bk = _pick_block(k.shape[1])
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=_interpret(interpret),
    )
    return jnp.swapaxes(out, 1, 2)


def _pick_block(s: int, target: int = 256) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


def _fa_fwd(q, k, v, causal, window, interpret):
    return flash_attention(q, k, v, causal, window, interpret), (q, k, v)


def _fa_bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal, window),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ------------------------------------------------------------------- rwkv6
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def rwkv6(r, k, v, logw, u, state0, interpret=None):
    """All inputs [B,S,H,Dh] (u: [H,Dh]; state0: [B,H,Dh,Dh] fp32).
    Returns (out [B,S,H,Dh], state [B,H,Dh,Dh])."""
    args = [jnp.swapaxes(t, 1, 2) for t in (r, k, v, logw)]
    out, state = rwkv6_bhsd(*args, u, state0.astype(jnp.float32),
                            interpret=_interpret(interpret))
    return jnp.swapaxes(out, 1, 2), state


def _rwkv_fwd(r, k, v, logw, u, state0, interpret):
    return rwkv6(r, k, v, logw, u, state0, interpret), (r, k, v, logw, u, state0)


def _rwkv_bwd(interpret, res, g):
    r, k, v, logw, u, state0 = res
    _, vjp = jax.vjp(
        lambda *a: ref.rwkv6_ref(*a), r, k, v, logw, u, state0
    )
    return vjp(g)


rwkv6.defvjp(_rwkv_fwd, _rwkv_bwd)


# ------------------------------------------------------------------- mamba
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def mamba_scan(u, dt, A, B_, C_, h0, interpret=None):
    """u/dt [B,S,Di]; A [Di,St]; B_/C_ [B,S,St]; h0 [B,Di,St] fp32.
    Returns (y [B,S,Di], h [B,Di,St])."""
    return mamba_scan_bsd(u, dt, A, B_, C_, h0.astype(jnp.float32),
                          interpret=_interpret(interpret))


def _mamba_fwd(u, dt, A, B_, C_, h0, interpret):
    return mamba_scan(u, dt, A, B_, C_, h0, interpret), (u, dt, A, B_, C_, h0)


def _mamba_bwd(interpret, res, g):
    u, dt, A, B_, C_, h0 = res
    _, vjp = jax.vjp(lambda *a: ref.mamba_ref(*a), u, dt, A, B_, C_, h0)
    return vjp(g)


mamba_scan.defvjp(_mamba_fwd, _mamba_bwd)
