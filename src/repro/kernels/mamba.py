"""Mamba selective scan as a Pallas TPU kernel.

Blocking: grid ``(B, Di/bd, S/L)`` — channel blocks are parallel (each owns
an independent [bd, St] state slice; Mamba's recurrence never mixes
channels), the chunk axis is innermost/sequential with the fp32 state in
VMEM scratch. Within a chunk the timestep loop runs over VMEM-resident
tiles (``fori_loop`` over L), so HBM traffic is one read of u/dt/B/C and one
write of y per element — the memory-bound optimum for this op; the CUDA
version's warp-parallel scan becomes block-sequential VPU work here because
TPU has no cross-lane shuffle, and channel-block parallelism supplies the
occupancy instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(
    u_ref, dt_ref,  # [1, L, bd]
    a_ref,  # [bd, St]
    b_ref, c_ref,  # [1, L, St]
    h0_ref,  # [1, bd, St]
    y_ref,  # [1, L, bd]
    hout_ref,  # [1, bd, St]
    h_scr,  # VMEM [bd, St] fp32
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # [L, bd]
    dt = dt_ref[0].astype(jnp.float32)  # [L, bd]
    A = a_ref[...].astype(jnp.float32)  # [bd, St]
    B_ = b_ref[0].astype(jnp.float32)  # [L, St]
    C_ = c_ref[0].astype(jnp.float32)  # [L, St]

    def step(t, carry):
        h, ys = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]  # [bd]
        u_t = jax.lax.dynamic_slice_in_dim(u, t, 1, 0)[0]
        b_t = jax.lax.dynamic_slice_in_dim(B_, t, 1, 0)[0]  # [St]
        c_t = jax.lax.dynamic_slice_in_dim(C_, t, 1, 0)[0]
        a = jnp.exp(dt_t[:, None] * A)  # [bd, St]
        h = a * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)  # [bd]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_t[None, :], t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, u.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = h
    y_ref[0, :, :] = ys.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _done():
        hout_ref[0, :, :] = h


def mamba_scan_bsd(
    u: jax.Array,  # [B, S, Di]
    dt: jax.Array,  # [B, S, Di]
    A: jax.Array,  # [Di, St]
    B_: jax.Array,  # [B, S, St]
    C_: jax.Array,  # [B, S, St]
    h0: jax.Array,  # [B, Di, St] fp32
    *,
    chunk: int = 64,
    block_d: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, s, di = u.shape
    st = A.shape[-1]
    bd = min(block_d, di)
    assert s % chunk == 0 and di % bd == 0, (s, chunk, di, bd)
    nc, nd = s // chunk, di // bd
    grid = (b, nd, nc)
    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, id_, ic: (b_, ic, id_)),
            pl.BlockSpec((1, chunk, bd), lambda b_, id_, ic: (b_, ic, id_)),
            pl.BlockSpec((bd, st), lambda b_, id_, ic: (id_, 0)),
            pl.BlockSpec((1, chunk, st), lambda b_, id_, ic: (b_, ic, 0)),
            pl.BlockSpec((1, chunk, st), lambda b_, id_, ic: (b_, ic, 0)),
            pl.BlockSpec((1, bd, st), lambda b_, id_, ic: (b_, id_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b_, id_, ic: (b_, ic, id_)),
            pl.BlockSpec((1, bd, st), lambda b_, id_, ic: (b_, id_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), u.dtype),
            jax.ShapeDtypeStruct((b, di, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, st), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(u, dt, A, B_, C_, h0)
    return y, h
